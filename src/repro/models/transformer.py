"""Composable decoder-only model covering every assigned architecture:

  dense GQA/MQA/MHA (smollm, yi, granite, phi3, musicgen)
  MLA + MoE (deepseek-v2-lite), MoE (olmoe)
  cross-attention VLM (llama-3.2-vision backbone)
  Mamba2 + shared-attention hybrid (zamba2)
  RWKV6 (Finch)

Layers are stacked and scanned (compact HLO, fast compiles); remat policy is
configurable. ``Model.loss`` is the training objective; ``Model.decode_step``
is the single-token serving step against an explicit cache pytree.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import layers as ll
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (TP_AXIS, Initializer, ModelConfig,
                                 axis_size, data_axes, tree_specs)


def _remat_policy(name: str):
    if name == "none":
        return None
    if name == "full":
        return jax.checkpoint_policies.nothing_saveable
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    raise ValueError(name)


def _dp_for(mesh, dim: int):
    if mesh is None:
        return None
    dp = data_axes(mesh)
    n = 1
    for a in dp:
        n *= axis_size(mesh, a)
    return dp if (n > 1 and dim % n == 0) else None


class Model:
    def __init__(self, cfg: ModelConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.param_specs: Dict[str, Any] = {}

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------

    def _wsc(self, x, *spec):
        if self.mesh is None or getattr(self.mesh, "empty", False):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def _dp(self, dim: int):
        return _dp_for(self.mesh, dim)

    def _sp(self, x):
        """Sequence-parallel residual constraint: shards the hidden state's
        sequence dim over the model axis so XLA lowers TP partial-sum
        all-reduces into reduce-scatter + all-gather (half the bytes)."""
        cfg = self.cfg
        if not cfg.seq_parallel or self.mesh is None:
            return x
        m = axis_size(self.mesh, TP_AXIS)
        if m <= 1 or x.ndim < 3 or x.shape[1] % m:
            return x
        return self._wsc(x, self._dp(x.shape[0]), TP_AXIS, None)

    def _loop(self, body, carry, xs, length: int):
        """lax.scan when cfg.scan_layers else an unrolled Python loop (used by
        the dry-run so cost_analysis sees every layer's FLOPs/collectives)."""
        if self.cfg.scan_layers:
            return jax.lax.scan(body, carry, xs, length=length)
        ys_acc = []
        for i in range(length):
            sl = jax.tree.map(lambda x: x[i], xs) if xs is not None else None
            carry, y = body(carry, sl)
            ys_acc.append(y)
        if not ys_acc or all(y is None for y in ys_acc):
            return carry, None
        ys = jax.tree.map(lambda *zs: jnp.stack(zs, 0), *ys_acc)
        return carry, ys

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------

    def init(self, seed: int = 0, abstract: bool = False):
        cfg = self.cfg
        ini = Initializer(cfg, mesh=self.mesh, abstract=abstract, seed=seed)
        p: Dict[str, Any] = {}
        if not cfg.embedding_inputs:
            p["embed"] = ini.param("embed", (cfg.vocab_size, cfg.d_model),
                                   ("vocab", None), init="embed", scale=0.02)
        p.update(self._init_blocks(ini))
        p["final_norm"] = ll.init_rmsnorm(ini, "final_norm", cfg.d_model)
        if not cfg.tie_embeddings:
            p["lm_head"] = ini.param("lm_head", (cfg.d_model, cfg.vocab_size),
                                     (None, "vocab"), scale=0.02)
        self.param_specs = ini.specs
        return p

    def _init_attn_block(self, ini, path, stack, use_moe: bool):
        cfg = self.cfg
        blk = {
            "ln1": ll.init_rmsnorm(ini, f"{path}/ln1", cfg.d_model, stack),
            "ln2": ll.init_rmsnorm(ini, f"{path}/ln2", cfg.d_model, stack),
        }
        if cfg.mla:
            blk["attn"] = ll.init_mla(ini, f"{path}/attn", cfg, stack)
        else:
            blk["attn"] = ll.init_attention(ini, f"{path}/attn", cfg, stack)
        if use_moe:
            blk["moe"] = moe_mod.init_moe(ini, f"{path}/moe", cfg, stack)
            if cfg.d_ff_shared:
                blk["shared"] = ll.init_mlp(ini, f"{path}/shared", cfg.d_model,
                                            cfg.d_ff_shared, stack)
        else:
            blk["mlp"] = ll.init_mlp(ini, f"{path}/mlp", cfg.d_model, cfg.d_ff, stack)
        return blk

    def _init_blocks(self, ini):
        cfg = self.cfg
        pat = cfg.block_pattern
        if pat == "attn":
            out = {}
            n_scan = cfg.num_layers - cfg.first_dense
            if cfg.first_dense:
                out["prefix"] = [self._init_attn_block(ini, f"prefix{i}", (), False)
                                 for i in range(cfg.first_dense)]
            if cfg.cross_attn_every:
                G = cfg.num_layers // cfg.cross_attn_every
                out["blocks"] = self._init_attn_block(
                    ini, "blocks", (G, cfg.cross_attn_every), cfg.moe)
                out["cross"] = ll.init_cross_attention(ini, "cross", cfg, (G,))
                out["cross_ln"] = ll.init_rmsnorm(ini, "cross_ln", cfg.d_model, (G,))
                out["cross_mlp"] = ll.init_mlp(ini, "cross_mlp", cfg.d_model, cfg.d_ff, (G,))
                out["cross_ln2"] = ll.init_rmsnorm(ini, "cross_ln2", cfg.d_model, (G,))
            else:
                out["blocks"] = self._init_attn_block(ini, "blocks", (n_scan,), cfg.moe)
            return out
        if pat == "rwkv6":
            L = cfg.num_layers
            return {"blocks": {
                "ln1": ll.init_rmsnorm(ini, "blocks/ln1", cfg.d_model, (L,)),
                "tm": ssm_mod.init_rwkv6_tm(ini, "blocks/tm", cfg, (L,)),
                "ln2": ll.init_rmsnorm(ini, "blocks/ln2", cfg.d_model, (L,)),
                "cm": ssm_mod.init_rwkv6_cm(ini, "blocks/cm", cfg, (L,)),
            }}
        if pat == "zamba2":
            M = cfg.shared_attn_every
            G = cfg.num_layers // M
            return {
                "blocks": {
                    "ln": ll.init_rmsnorm(ini, "blocks/ln", cfg.d_model, (G, M)),
                    "mamba": ssm_mod.init_mamba2(ini, "blocks/mamba", cfg, (G, M)),
                },
                "shared_attn": self._init_attn_block(ini, "shared_attn", (), False),
            }
        raise ValueError(pat)

    # ------------------------------------------------------------------
    # block application
    # ------------------------------------------------------------------

    def _attn_block(self, p, x, positions, cache, cache_index, use_moe):
        cfg = self.cfg
        x = self._sp(x)
        h = ll.rmsnorm(p["ln1"], x, cfg.norm_eps, fast=cfg.fast_norm)
        if cfg.mla:
            a, new_cache = ll.mla_attention(p["attn"], h, cfg, positions=positions,
                                            cache=cache, cache_index=cache_index)
        else:
            a, new_cache = ll.attention(p["attn"], h, cfg, positions=positions,
                                        cache=cache, cache_index=cache_index)
        x = self._sp(x + a)
        h = ll.rmsnorm(p["ln2"], x, cfg.norm_eps, fast=cfg.fast_norm)
        aux = jnp.zeros((), jnp.float32)
        if use_moe:
            y, aux = moe_mod.moe_layer(p["moe"], h, cfg, self.mesh)
            if cfg.d_ff_shared:
                y = y + ll.mlp(p["shared"], h, cfg.cdtype)
        else:
            y = ll.mlp(p["mlp"], h, cfg.cdtype)
        return self._sp(x + y), new_cache, aux

    def _run_blocks(self, params, x, positions, cache, cache_index, patches=None):
        """Returns (x, new_cache, aux). cache None => training path."""
        cfg = self.cfg
        pat = cfg.block_pattern
        decode = cache is not None
        policy = None if (decode or cfg.remat == "none") else _remat_policy(cfg.remat)
        aux_total = jnp.zeros((), jnp.float32)
        new_cache: Dict[str, Any] = {}

        if pat == "attn":
            if cfg.first_dense:
                pre_caches = []
                for i, blk in enumerate(params["prefix"]):
                    c = cache["prefix"][i] if decode else None
                    x, nc, aux = self._attn_block(blk, x, positions, c, cache_index, False)
                    pre_caches.append(nc)
                    aux_total += aux
                if decode:
                    new_cache["prefix"] = pre_caches

            if cfg.cross_attn_every:
                x, nc, aux = self._vlm_groups(params, x, positions, cache, cache_index,
                                              patches, policy)
                if decode:
                    new_cache.update(nc)
                aux_total += aux
            else:
                def body(carry, xs):
                    h, aux = carry
                    blk, csl = xs
                    h, nc, a = self._attn_block(blk, h, positions, csl, cache_index, cfg.moe)
                    return (h, aux + a), nc
                if policy is not None:
                    body = jax.checkpoint(body, policy=policy)
                xs = (params["blocks"], cache["blocks"] if decode else None)
                if not decode:
                    n = jax.tree.leaves(params["blocks"])[0].shape[0]
                    (x, aux), _ = self._loop(
                        lambda c, blk: body(c, (blk, None)),
                        (x, aux_total), params["blocks"], n)
                    aux_total = aux
                else:
                    n = jax.tree.leaves(params["blocks"])[0].shape[0]
                    (x, aux_total), ncs = self._loop(body, (x, aux_total), xs, n)
                    new_cache["blocks"] = ncs
            return x, (new_cache if decode else None), aux_total

        if pat == "rwkv6":
            def body(carry, xs):
                h = carry
                blk, csl = xs
                tm_state = None
                cm_state = None
                if decode:
                    tm_state = {"tm_shift": csl["tm_shift"], "wkv": csl["wkv"]}
                    cm_state = {"cm_shift": csl["cm_shift"]}
                a, tm_new = ssm_mod.rwkv6_time_mix(
                    blk["tm"], ll.rmsnorm(blk["ln1"], h, cfg.norm_eps,
                                          fast=cfg.fast_norm), cfg, state=tm_state)
                h = self._sp(h + a)
                m, cm_new = ssm_mod.rwkv6_channel_mix(
                    blk["cm"], ll.rmsnorm(blk["ln2"], h, cfg.norm_eps,
                                          fast=cfg.fast_norm), cfg, state=cm_state)
                h = self._sp(h + m)
                nc = None
                if decode:
                    nc = {"tm_shift": tm_new["tm_shift"], "wkv": tm_new["wkv"],
                          "cm_shift": cm_new["cm_shift"]}
                return h, nc
            if policy is not None:
                body = jax.checkpoint(body, policy=policy)
            n = jax.tree.leaves(params["blocks"])[0].shape[0]
            if not decode:
                x, _ = self._loop(lambda c, blk: body(c, (blk, None)), x,
                                  params["blocks"], n)
            else:
                x, ncs = self._loop(body, x, (params["blocks"], cache["blocks"]), n)
                new_cache["blocks"] = ncs
            return x, (new_cache if decode else None), aux_total

        if pat == "zamba2":
            shared = params["shared_attn"]

            def group(carry, xs):
                h = carry
                gp, csl = xs  # gp: {"ln": (M,d), "mamba": (M,...)}; csl per group

                def inner(hc, ys):
                    hh = hc
                    lp, msl = ys
                    z = ll.rmsnorm({"scale": lp["ln"]}, hh, cfg.norm_eps,
                                   fast=cfg.fast_norm)
                    out, mst = ssm_mod.mamba2_layer(lp["mamba"], z, cfg, state=msl)
                    return self._sp(hh + out), mst

                inner_xs = ({"ln": gp["ln"]["scale"], "mamba": gp["mamba"]},
                            csl["mamba"] if decode else None)
                M = gp["ln"]["scale"].shape[0]
                if not decode:
                    h, _ = self._loop(lambda c, lp: inner(c, (lp, None)),
                                      h, inner_xs[0], M)
                    m_new = None
                else:
                    h, m_new = self._loop(inner, h, inner_xs, M)

                h, attn_c, _ = self._attn_block(
                    shared, h, positions, csl["attn"] if decode else None,
                    cache_index, False)
                nc = {"mamba": m_new, "attn": attn_c} if decode else None
                return h, nc

            if policy is not None:
                group = jax.checkpoint(group, policy=policy)
            G = jax.tree.leaves(params["blocks"])[0].shape[0]
            if not decode:
                x, _ = self._loop(lambda c, gp: group(c, (gp, None)), x,
                                  params["blocks"], G)
            else:
                x, ncs = self._loop(group, x, (params["blocks"], cache["blocks"]), G)
                new_cache["blocks"] = ncs
            return x, (new_cache if decode else None), aux_total

        raise ValueError(pat)

    def _vlm_groups(self, params, x, positions, cache, cache_index, patches, policy):
        cfg = self.cfg
        decode = cache is not None
        dt = cfg.cdtype

        def group(carry, xs):
            h, aux = carry
            gp, csl = xs

            def inner(hc, ys):
                hh, a_in = hc
                lp, sl = ys
                hh, nc, a = self._attn_block(lp, hh, positions, sl, cache_index, cfg.moe)
                return (hh, a_in + a), nc

            if not decode:
                M = cfg.cross_attn_every
                (h, aux), _ = self._loop(
                    lambda c, lp: inner(c, (lp, None)), (h, aux), gp["blocks"], M)
                self_new = None
            else:
                (h, aux), self_new = self._loop(inner, (h, aux),
                                                (gp["blocks"], csl["self"]),
                                                cfg.cross_attn_every)
            # cross-attention sublayer
            z = ll.rmsnorm(gp["cross_ln"], h, cfg.norm_eps, fast=cfg.fast_norm)
            kvc = csl["cross_kv"] if decode else None
            h = self._sp(h + ll.cross_attention(gp["cross"], z, patches, cfg,
                                                kv_cache=kvc))
            z = ll.rmsnorm(gp["cross_ln2"], h, cfg.norm_eps, fast=cfg.fast_norm)
            h = self._sp(h + ll.mlp(gp["cross_mlp"], z, dt))
            nc = {"self": self_new, "cross_kv": kvc} if decode else None
            return (h, aux), nc

        if policy is not None:
            group = jax.checkpoint(group, policy=policy)
        gparams = {"blocks": params["blocks"], "cross": params["cross"],
                   "cross_ln": params["cross_ln"], "cross_mlp": params["cross_mlp"],
                   "cross_ln2": params["cross_ln2"]}
        aux0 = jnp.zeros((), jnp.float32)
        G = cfg.num_layers // cfg.cross_attn_every
        if not decode:
            (x, aux), _ = self._loop(lambda c, gp: group(c, (gp, None)),
                                     (x, aux0), gparams, G)
            return x, None, aux
        (x, aux), ncs = self._loop(group, (x, aux0),
                                   (gparams, cache["cross_groups"]), G)
        return x, {"cross_groups": ncs}, aux

    # ------------------------------------------------------------------
    # forward / loss / decode
    # ------------------------------------------------------------------

    def _embed_in(self, params, batch):
        cfg = self.cfg
        dt = cfg.cdtype
        if cfg.embedding_inputs:
            x = batch["embeds"].astype(dt)
        else:
            x = params["embed"].astype(dt)[batch["tokens"]]
        return self._wsc(x, self._dp(x.shape[0]), None, None)

    def _logits(self, params, x):
        cfg = self.cfg
        h = ll.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", h, head.astype(cfg.cdtype))
        return self._wsc(logits, self._dp(x.shape[0]), None, TP_AXIS if self.mesh is not None and axis_size(self.mesh, TP_AXIS) > 1 and cfg.vocab_size % axis_size(self.mesh, TP_AXIS) == 0 else None)

    def forward(self, params, batch):
        cfg = self.cfg
        x = self._embed_in(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        patches = batch.get("patches")
        if patches is not None:
            patches = patches.astype(cfg.cdtype)
        x, _, aux = self._run_blocks(params, x, positions, None, None, patches=patches)
        return self._logits(params, x), aux

    def loss(self, params, batch):
        cfg = self.cfg
        labels = batch["labels"]
        if cfg.logit_chunk and labels.shape[1] % cfg.logit_chunk == 0 and labels.shape[1] > cfg.logit_chunk:
            return self._loss_chunked(params, batch)
        logits, aux = self.forward(params, batch)
        ce, n = _masked_ce(logits, labels)
        loss = ce + cfg.router_aux_coef * aux
        return loss, {"ce": ce, "aux": aux, "tokens": n}

    def _loss_chunked(self, params, batch):
        cfg = self.cfg
        x = self._embed_in(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        patches = batch.get("patches")
        if patches is not None:
            patches = patches.astype(cfg.cdtype)
        x, _, aux = self._run_blocks(params, x, positions, None, None, patches=patches)
        h = ll.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(cfg.cdtype)
        C = cfg.logit_chunk
        n_chunks = S // C
        hs = h.reshape(h.shape[0], n_chunks, C, h.shape[-1]).transpose(1, 0, 2, 3)
        ls = batch["labels"].reshape(h.shape[0], n_chunks, C).transpose(1, 0, 2)

        def body(acc, xs):
            hc, lc = xs
            logits = jnp.einsum("bsd,dv->bsv", hc, head)
            s, n = _masked_ce_sums(logits, lc)
            return (acc[0] + s, acc[1] + n), None

        (tot, n), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),) * 2, (hs, ls))
        ce = tot / jnp.maximum(n, 1.0)
        loss = ce + cfg.router_aux_coef * aux
        return loss, {"ce": ce, "aux": aux, "tokens": n}

    def decode_step(self, params, cache, batch, cache_index):
        """One-token decode: batch has tokens (B,1) or embeds (B,1,d) (+ patches
        pre-cached). Returns (logits (B,1,V), new_cache)."""
        x = self._embed_in(params, batch)
        B = x.shape[0]
        positions = jnp.broadcast_to(jnp.asarray(cache_index)[None, None], (B, 1))
        x, new_cache, _ = self._run_blocks(params, x, positions, cache, cache_index)
        return self._logits(params, x), new_cache

    # ------------------------------------------------------------------
    # cache construction (+ sharding specs)
    # ------------------------------------------------------------------

    def init_cache(self, B: int, S_max: int, abstract: bool = False):
        """Returns (cache, spec_tree)."""
        cfg = self.cfg
        mesh = self.mesh
        dp = self._dp(B)
        tp = TP_AXIS if (mesh is not None and axis_size(mesh, TP_AXIS) > 1) else None

        def mk(shape, dtype, spec):
            spec = P(*spec)
            if abstract:
                sh = NamedSharding(mesh, spec) if mesh is not None else None
                return jax.ShapeDtypeStruct(shape, dtype, sharding=sh), spec
            return jnp.zeros(shape, dtype), spec

        def kv_pair(stack, KH, Dh, S=S_max):
            # kv heads sharded if divisible, else shard sequence on model
            if tp and KH % axis_size(mesh, TP_AXIS) == 0:
                sp = (*(None,) * len(stack), dp, None, tp, None)
            elif tp and S % axis_size(mesh, TP_AXIS) == 0:
                sp = (*(None,) * len(stack), dp, tp, None, None)
            else:
                sp = (*(None,) * len(stack), dp, None, None, None)
            k, ks = mk((*stack, B, S, KH, Dh), cfg.cdtype, sp)
            v, vs = mk((*stack, B, S, KH, Dh), cfg.cdtype, sp)
            return {"k": k, "v": v}, {"k": ks, "v": vs}

        pat = cfg.block_pattern
        cache, specs = {}, {}
        if pat == "attn":
            if cfg.mla:
                def mla_pair(stack):
                    sp_c = (*(None,) * len(stack), dp,
                            tp if (tp and S_max % axis_size(mesh, TP_AXIS) == 0) else None,
                            None)
                    c, cs = mk((*stack, B, S_max, cfg.kv_lora_rank), cfg.cdtype, sp_c)
                    r, rs = mk((*stack, B, S_max, cfg.qk_rope_dim), cfg.cdtype, sp_c)
                    return {"c_kv": c, "k_rope": r}, {"c_kv": cs, "k_rope": rs}
                if cfg.first_dense:
                    pre = [mla_pair(()) for _ in range(cfg.first_dense)]
                    cache["prefix"] = [c for c, _ in pre]
                    specs["prefix"] = [s for _, s in pre]
                n_scan = cfg.num_layers - cfg.first_dense
                cache["blocks"], specs["blocks"] = mla_pair((n_scan,))
            elif cfg.cross_attn_every:
                G = cfg.num_layers // cfg.cross_attn_every
                M = cfg.cross_attn_every
                sc, ss = kv_pair((G, M), cfg.num_kv_heads, cfg.head_dim)
                cc, cs = kv_pair((G,), cfg.num_kv_heads, cfg.head_dim, S=cfg.num_patches)
                cache["cross_groups"] = {"self": sc, "cross_kv": cc}
                specs["cross_groups"] = {"self": ss, "cross_kv": cs}
            else:
                cache["blocks"], specs["blocks"] = kv_pair(
                    (cfg.num_layers,), cfg.num_kv_heads, cfg.head_dim)
        elif pat == "rwkv6":
            L = cfg.num_layers
            H, hd = ssm_mod.rwkv6_dims(cfg)
            t, ts = mk((L, B, cfg.d_model), cfg.cdtype, (None, dp, None))
            c, cs = mk((L, B, cfg.d_model), cfg.cdtype, (None, dp, None))
            w, ws = mk((L, B, H, hd, hd), jnp.float32, (None, dp, None, None, None))
            cache["blocks"] = {"tm_shift": t, "cm_shift": c, "wkv": w}
            specs["blocks"] = {"tm_shift": ts, "cm_shift": cs, "wkv": ws}
        elif pat == "zamba2":
            M = cfg.shared_attn_every
            G = cfg.num_layers // M
            d_inner, H, conv_dim = ssm_mod.mamba2_dims(cfg)
            htp = tp if (tp and H % axis_size(mesh, TP_AXIS) == 0) else None
            cv, cvs = mk((G, M, B, cfg.conv_kernel - 1, conv_dim), cfg.cdtype,
                         (None, None, dp, None, htp and tp))
            sm, sms = mk((G, M, B, H, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32,
                         (None, None, dp, htp, None, None))
            ac, acs = kv_pair((G,), cfg.num_kv_heads, cfg.head_dim)
            cache["blocks"] = {"mamba": {"conv": cv, "ssm": sm}, "attn": ac}
            specs["blocks"] = {"mamba": {"conv": cvs, "ssm": sms}, "attn": acs}
        else:
            raise ValueError(pat)
        return cache, specs

    def param_spec_tree(self, params):
        return tree_specs(self.param_specs, params)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def _masked_ce_sums(logits, labels):
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - gold) * mask), jnp.sum(mask)


def _masked_ce(logits, labels):
    s, n = _masked_ce_sums(logits, labels)
    return s / jnp.maximum(n, 1.0), n
