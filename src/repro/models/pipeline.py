"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

For 1000+-node scale, DP×TP alone stops paying once the per-layer
collectives dominate; this module shards the *layer stack* across a
``stage`` axis and streams microbatches through it with
``collective_permute`` hops — fill/drain schedule, static shapes, AD-able
(jax.grad flows through the permutes), compatible with the scanned layer
stacks used everywhere else.

Scope: the homogeneous dense family (block_pattern == "attn", no MoE
prefix/cross groups), which is where PP is used in practice at these
scales. Embedding/head stay outside the staged region (replicated over
``stage``). Verified numerically against the unstaged model in the
8-device subprocess test and dry-run-lowered on a (data, stage) mesh.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig
from repro.models import layers as ll

STAGE_AXIS = "stage"


def _stage_block(cfg: ModelConfig, blk, x, positions):
    h = ll.rmsnorm(blk["ln1"], x, cfg.norm_eps, fast=cfg.fast_norm)
    a, _ = ll.attention(blk["attn"], h, cfg, positions=positions)
    x = x + a
    h = ll.rmsnorm(blk["ln2"], x, cfg.norm_eps, fast=cfg.fast_norm)
    return x + ll.mlp(blk["mlp"], h, cfg.cdtype)


def pp_apply_blocks(cfg: ModelConfig, params_blocks, x, positions, mesh,
                    n_micro: int):
    """x: (B, S, d) global hidden states after embedding. params_blocks: the
    stacked (L, ...) block params. Returns (B, S, d) after all layers,
    pipelined over the ``stage`` mesh axis with ``n_micro`` microbatches."""
    K = mesh.shape[STAGE_AXIS]
    L = jax.tree.leaves(params_blocks)[0].shape[0]
    assert L % K == 0, (L, K)
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    # params resharded: leading L split into (K, L/K) with K on the stage axis
    staged = jax.tree.map(lambda w: w.reshape(K, L // K, *w.shape[1:]),
                          params_blocks)
    xs = x.reshape(n_micro, mb, *x.shape[1:])

    def local(xs_loc, params_loc):
        # params_loc: (1, L/K, ...) this rank's stage; xs_loc replicated
        my = jax.lax.axis_index(STAGE_AXIS)
        stage_params = jax.tree.map(lambda w: w[0], params_loc)

        T = n_micro + K - 1
        buf = jnp.zeros_like(xs_loc[0])            # activation in flight
        out = jnp.zeros_like(xs_loc)               # filled on the last stage

        def tick(carry, t):
            buf, out = carry
            # stage 0 injects microbatch t (if valid)
            inject = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(my == 0, xs_loc[inject], buf)

            def body(h, blk):
                return _stage_block(cfg, blk, h, positions), None
            y, _ = jax.lax.scan(body, x_in, stage_params)

            # last stage stores finished microbatch t-(K-1)
            slot = jnp.clip(t - (K - 1), 0, n_micro - 1)
            valid = (my == K - 1) & (t >= K - 1)
            out = jax.lax.dynamic_update_slice(
                out, jnp.where(valid, y, out[slot])[None], (slot,) + (0,) * y.ndim)
            # pass activation to the next stage
            perm = [(i, (i + 1) % K) for i in range(K)]
            buf = jax.lax.ppermute(y, STAGE_AXIS, perm)
            return (buf, out), None

        (buf, out), _ = jax.lax.scan(tick, (buf, out), jnp.arange(T))
        # only the last stage holds real outputs -> psum the masked buffer
        out = jnp.where(my == K - 1, out, jnp.zeros_like(out))
        out = jax.lax.psum(out, STAGE_AXIS)
        return out

    pspec = jax.tree.map(lambda _: P(STAGE_AXIS), staged)
    from repro.compat import shard_map
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, dp if dp else None), pspec),
        out_specs=P(None, dp if dp else None))
    out = fn(xs, staged)
    return out.reshape(B, *x.shape[1:])


def pp_loss_fn(model, mesh, n_micro: int):
    """Drop-in loss for the dense family with the block stack pipelined."""
    cfg = model.cfg

    def loss(params, batch):
        x = model._embed_in(params, batch)
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        x = pp_apply_blocks(cfg, params["blocks"], x, positions, mesh, n_micro)
        logits = model._logits(params, x)
        from repro.models.transformer import _masked_ce
        ce, n = _masked_ce(logits, batch["labels"])
        return ce, {"ce": ce, "tokens": n}

    return loss
